"""LM backbone assembly: one config-driven forward covering all 10 assigned
architectures (dense / GQA / MLA / MoE / SWA / local-global+softcap / RWKV6 /
hybrid attn+mamba), backend-generic (JOps for train/serve, CaaOps for the
paper's rigorous error analysis).

Layers are stacked along a leading axis and iterated with
``backend.layer_loop`` (lax.scan under JOps — O(1) HLO in depth, which is
what keeps 512-device compiles of 56-layer models tractable).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import moe as M
from . import ssm as S


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None      # default d_model // n_heads
    act: str = "silu"
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    softcap_attn: Optional[float] = None
    softcap_final: Optional[float] = None
    window: Optional[int] = None               # SWA for every attn layer
    local_global_period: Optional[int] = None  # gemma2: even layers local
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    embed_scale: bool = False                  # gemma-style sqrt(d) scaling
    # MoE
    n_experts: Optional[int] = None
    top_k: Optional[int] = None
    moe_d_ff: Optional[int] = None
    # MLA
    mla: bool = False
    q_rank: int = 768
    kv_rank: int = 256
    d_nope: int = 64
    d_rope: int = 32
    d_v: int = 64
    # SSM / hybrid
    rwkv: bool = False
    hybrid: bool = False
    ssm_state: int = 16
    mamba_expand: int = 2
    # enc-dec (whisper) & modality frontends (stubs per assignment)
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None   # 'audio' | 'vision'
    frontend_seq: int = 0            # frames / patches supplied by the stub
    frontend_dim: int = 0            # stub embedding dim
    max_decode_seq: int = 448        # whisper decoder context cap

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """True if decode state is O(1) in context (rwkv) or the arch is
        hybrid with bounded-window attention — the long_500k gate."""
        return self.rwkv or self.hybrid

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    def param_count(self, params=None) -> int:
        if params is None:
            return -1
        return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, dh = cfg.d_model, cfg.head_dim
    p: Dict[str, Any] = {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cfg.norm == "layernorm":
        p["ln1_b"] = jnp.zeros((d,), jnp.float32)
        p["ln2_b"] = jnp.zeros((d,), jnp.float32)
    if cfg.rwkv:
        p["tmix"] = S.init_rwkv_tmix(ks[0], d, cfg.n_heads)
        p["cmix"] = S.init_rwkv_cmix(ks[1], d, cfg.d_ff)
        return p
    if cfg.mla:
        p["attn"] = A.init_mla(ks[0], d, cfg.n_heads, cfg.q_rank, cfg.kv_rank,
                               cfg.d_nope, cfg.d_rope, cfg.d_v)
    else:
        p["attn"] = A.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads, dh,
                               cfg.qkv_bias)
    if cfg.hybrid:
        p["mamba"] = S.init_mamba(ks[1], d, cfg.mamba_expand * d, cfg.ssm_state)
    if cfg.family == "moe":
        p["moe"] = M.init_moe(ks[2], d, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = {
            "w_gate": L.dense_init(ks[3], d, cfg.d_ff),
            "w_up": L.dense_init(ks[4], d, cfg.d_ff),
            "w_down": L.dense_init(ks[5], cfg.d_ff, d),
        }
    return p


def init_params(key, cfg: ArchConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_head, k_enc, k_fr = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": L.embed_init(k_emb, cfg.vocab, cfg.d_model),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.embed_init(k_head, cfg.vocab, cfg.d_model)
    if cfg.enc_dec:
        enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
        enc_cfg = dataclasses.replace(cfg, rwkv=False, hybrid=False,
                                      mla=False, family="dense")
        params["enc_layers"] = jax.vmap(lambda k: _init_layer(k, enc_cfg))(enc_keys)
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params["cross"] = jax.vmap(
            lambda k: A.init_gqa(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim)
        )(jax.random.split(k_enc, cfg.n_layers))
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(
            k_fr, cfg.frontend_dim, cfg.d_model
        )
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _norm(bk, x, p, cfg, which: str):
    if cfg.norm == "layernorm":
        return L.layernorm(bk, x, p[which], p[which + "_b"])
    return L.rmsnorm(bk, x, p[which])


def _mlp_or_moe(bk, x, p, cfg: ArchConfig):
    if cfg.family == "moe":
        return M.moe_mlp(bk, x, p["moe"], n_experts=cfg.n_experts,
                         top_k=cfg.top_k, act=cfg.act)
    return L.mlp_gated(bk, x, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], cfg.act)


def _layer_masks(cfg: ArchConfig, q_len: int, kv_len: int, q_offset=0):
    """(global_mask, local_mask_or_None) as exact booleans."""
    gmask = L.causal_mask(q_len, kv_len, q_offset, cfg.window)
    lmask = None
    if cfg.local_global_period:
        lmask = L.causal_mask(q_len, kv_len, q_offset,
                              cfg.local_global_period)
    return gmask, lmask


def forward(
    bk, params, cfg: ArchConfig, tokens=None, *,
    embeds=None,
    frontend_embeds=None,
    enc_embeds=None,
    enc_out=None,              # precomputed encoder states (decode reuse)
    cache=None,                # stacked per-layer cache pytree or None
    q_offset=0,
) -> Tuple[Any, Any]:
    """Returns (logits, new_cache). ``tokens``: [B, S] int32.

    ``frontend_embeds`` ([B, P, frontend_dim]) come from the modality stub
    (audio frames / vision patches) and are projected+prepended.
    ``enc_embeds`` are the whisper encoder-stub frames.
    """
    # named scopes bound the certified per-scope precision maps: "embed" /
    # "layer{i}" / "head" are the keys mixed/format certificates assign and
    # the serving backends resolve (repro.certify.lm ↔ launch/serve.py)
    with bk.scope("embed"):
        if embeds is None:
            x = L.embed(bk, params["embed"], tokens)
        else:
            x = embeds
        if cfg.embed_scale:
            x = bk.scale(x, math.sqrt(cfg.d_model))

        if frontend_embeds is not None:
            fr = bk.matmul(bk.input(frontend_embeds),
                           bk.param(params["frontend_proj"]))
            x = bk.concat([fr, x], axis=1)

    B, Sq, _ = bk.shape_of(x)
    kv_len = _cache_len(cache) if cache is not None else Sq
    if kv_len < 0:
        kv_len = Sq  # rwkv: O(1) state, no KV buffer
    # ragged decode (continuous batching): q_offset is a [B] vector of
    # per-lane absolute positions — rope tables and masks become per-lane
    ragged = (not isinstance(q_offset, int)
              and getattr(q_offset, "ndim", 0) == 1)
    if ragged and cache is None:
        raise ValueError("per-lane q_offset requires a KV cache")
    if ragged:
        positions = q_offset[:, None] + jnp.arange(Sq)[None, :]  # [B, Sq]
    elif isinstance(q_offset, int):
        positions = jnp.arange(Sq) + q_offset
    else:
        positions = jnp.arange(Sq) + q_offset
    rope_positions = jnp.arange(kv_len) if cache is not None else positions
    cos_full, sin_full = L.rope_tables(rope_positions, _rope_dim(cfg),
                                       cfg.rope_theta)
    if ragged:
        cos_q = jnp.take(cos_full, positions, axis=0)   # [B, Sq, half]
        sin_q = jnp.take(sin_full, positions, axis=0)
        gmask = L.lane_causal_mask(Sq, kv_len, q_offset, cfg.window)
        lmask = (L.lane_causal_mask(Sq, kv_len, q_offset,
                                    cfg.local_global_period)
                 if cfg.local_global_period else None)
    else:
        cos_q = cos_full[-Sq:] if cache is None else _take_rows(cos_full, positions, Sq)
        sin_q = sin_full[-Sq:] if cache is None else _take_rows(sin_full, positions, Sq)
        gmask, lmask = _layer_masks(cfg, Sq, kv_len, q_offset)

    # the fused flash-decode hook only sees the plain-causal S==1 step —
    # every masking rule it reproduces in-kernel from the lane lengths
    fused_ok = (cache is not None and Sq == 1 and not cfg.mla
                and not cfg.rwkv and cfg.softcap_attn is None
                and cfg.window is None and cfg.local_global_period is None)

    if cfg.enc_dec and enc_out is None:
        # serve callers precompute this at prefill: re-encoding 1500 frames
        # for every decoded token was a 3300x HLO-flop bug (§Perf)
        enc_out = encode(bk, params, cfg, enc_embeds)

    def layer_fn(p, x, i, aux):
        x, aux_out = _one_layer(bk, p, x, i, aux, cfg, cos_q, sin_q,
                                gmask, lmask, enc_out, q_offset,
                                fused_ok=fused_ok)
        return x, aux_out

    lp = dict(params["layers"])
    if cfg.enc_dec:
        lp["cross"] = params["cross"]
    x, new_cache = bk.layer_loop(layer_fn, lp, x, cfg.n_layers, aux=cache)

    with bk.scope("head"):
        x = L.rmsnorm(bk, x, params["final_norm"])
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = L.logits_head(bk, x, head, cfg.softcap_final)
        logits = bk.record("logits", logits, kind="head")
    return logits, new_cache


def _rope_dim(cfg: ArchConfig) -> int:
    return cfg.d_rope if cfg.mla else cfg.head_dim


def _take_rows(table, positions, Sq):
    if isinstance(positions, jnp.ndarray) and positions.shape == (Sq,):
        return jnp.take(table, positions, axis=0)
    return table[-Sq:]


def _cache_len(cache) -> int:
    if isinstance(cache, dict) and "k" in cache:
        return int(cache["k"].shape[2])   # [L, B, Smax, ...]
    return -1


def _one_layer(bk, p, x, i, aux, cfg, cos, sin, gmask, lmask, enc_out,
               q_offset, fused_ok: bool = False):
    h = _norm(bk, x, p, cfg, "ln1")
    aux_out = None

    if cfg.rwkv:
        state = None
        if aux is not None:
            state = S.RwkvState(aux["S"], bk.value_of(bk.input(aux["x_tm"])))
        out, new_state = S.rwkv_tmix(bk, h, p["tmix"], n_heads=cfg.n_heads,
                                     state=state)
        x = bk.add(x, out)
        h2 = _norm(bk, x, p, cfg, "ln2")
        cm_prev = None if aux is None else aux["x_cm"]
        x = bk.add(x, S.rwkv_cmix(bk, h2, p["cmix"], cm_prev))
        if aux is not None:
            aux_out = {"S": new_state.S.astype(aux["S"].dtype),
                       "x_tm": new_state.x_prev.astype(aux["x_tm"].dtype),
                       "x_cm": bk.value_of(h2)[:, -1, :].astype(aux["x_cm"].dtype)}
        return x, aux_out

    # pick this layer's mask (gemma2 alternation: even layers local)
    mask = gmask
    if lmask is not None:
        is_local = (i % 2 == 0) if isinstance(i, int) else (i % 2 == 0)
        mask = jnp.where(is_local, lmask, gmask) if not isinstance(is_local, bool) \
            else (lmask if is_local else gmask)

    kv_cache = None
    if aux is not None:
        kv_cache = A.KVCache(aux["k"], aux["v"], aux["idx"])

    # named sub-layer scopes: per-scope knobs (formats, range lanes) can
    # resolve layer*/attn and layer*/mlp below per-layer granularity
    with bk.scope("attn"):
        if cfg.mla:
            out, new_kv = A.mla_attention(
                bk, h, p["attn"], n_heads=cfg.n_heads, q_rank=cfg.q_rank,
                kv_rank=cfg.kv_rank, d_nope=cfg.d_nope, d_rope=cfg.d_rope,
                d_v=cfg.d_v, cos=cos, sin=sin, mask=mask, cache=kv_cache,
                q_offset=q_offset)
        else:
            out, new_kv = A.gqa_attention(
                bk, h, p["attn"], n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim, cos=cos, sin=sin, mask=mask,
                softcap=cfg.softcap_attn, qkv_bias=cfg.qkv_bias,
                cache=kv_cache, q_offset=q_offset,
                fused_decode=fused_ok)

    h_ssm_out = None
    if cfg.hybrid:
        h0 = None if aux is None else aux.get("h_ssm")
        m_out, h_ssm_out = S.mamba_lite(bk, h, p["mamba"],
                                        d_state=cfg.ssm_state, h0=h0,
                                        return_state=True)
        out = bk.scale(bk.add(out, m_out), 0.5, exact_const=True)

    x = bk.add(x, out)

    if cfg.enc_dec and enc_out is not None:
        hc = _norm(bk, x, p, cfg, "ln1")
        c_out, _ = _cross_attention(bk, hc, enc_out, p["cross"], cfg)
        x = bk.add(x, c_out)

    h2 = _norm(bk, x, p, cfg, "ln2")
    with bk.scope("mlp"):
        mlp_out = _mlp_or_moe(bk, h2, p, cfg)
    x = bk.add(x, mlp_out)

    if new_kv is not None:
        aux_out = {"k": new_kv.k, "v": new_kv.v, "idx": new_kv.index}
        if h_ssm_out is not None:
            aux_out["h_ssm"] = h_ssm_out.astype(aux["h_ssm"].dtype)
    return x, aux_out


def _cross_attention(bk, x, enc_out, p, cfg: ArchConfig):
    """Decoder→encoder attention (whisper). No mask (full visibility)."""
    B, Sq, _ = bk.shape_of(x)
    Se = bk.shape_of(enc_out)[1]
    mask = jnp.ones((Sq, Se), bool)
    zeros = jnp.zeros(Se, jnp.float32)
    cos = jnp.ones((max(Sq, Se), cfg.head_dim // 2), jnp.float32)
    sin = jnp.zeros((max(Sq, Se), cfg.head_dim // 2), jnp.float32)

    # q from decoder, k/v from encoder — reuse GQA plumbing manually
    q = bk.matmul(x, bk.param(p["wq"]))
    k = bk.matmul(enc_out, bk.param(p["wk"]))
    v = bk.matmul(enc_out, bk.param(p["wv"]))
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    q = bk.reshape(q, (B, Sq, K, G, dh))
    k = bk.reshape(k, (B, Se, K, dh))
    v = bk.reshape(v, (B, Se, K, dh))
    scores = bk.scale(bk.einsum("bqkgd,bskd->bkgqs", q, k), dh ** -0.5)
    probs = bk.softmax(scores, axis=-1)
    out = bk.einsum("bkgqs,bskd->bqkgd", probs, v)
    if bk.is_analysis:
        vlo = jnp.min(v.exact.lo, axis=1)[:, None, :, None, :]
        vhi = jnp.max(v.exact.hi, axis=1)[:, None, :, None, :]
        out = bk.clamp_range(out, vlo, vhi)
    out = bk.reshape(out, (B, Sq, H * dh))
    return bk.matmul(out, bk.param(p["wo"])), None


def encode(bk, params, cfg: ArchConfig, enc_embeds):
    """Whisper encoder stack: bidirectional self-attention over the stub's
    frame embeddings (conv frontend is a stub per the assignment)."""
    x = bk.matmul(bk.input(enc_embeds), bk.param(params["frontend_proj"]))
    Se = bk.shape_of(x)[1]
    cos, sin = L.rope_tables(jnp.arange(Se), cfg.head_dim, cfg.rope_theta)
    mask = jnp.ones((Se, Se), bool)

    def layer_fn(p, x, i, aux):
        h = _norm(bk, x, p, cfg, "ln1")
        out, _ = A.gqa_attention(
            bk, h, p["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.head_dim, cos=cos, sin=sin, mask=mask)
        x = bk.add(x, out)
        h2 = _norm(bk, x, p, cfg, "ln2")
        x = bk.add(x, _mlp_or_moe(bk, h2, p, cfg))
        return x, None

    x, _ = bk.layer_loop(layer_fn, params["enc_layers"], x, cfg.n_enc_layers)
    return L.rmsnorm(bk, x, params["enc_norm"])


def analytic_params(cfg: ArchConfig, active: bool = False) -> int:
    """Closed-form parameter count (MoE: total vs active) — drives the
    roofline model and the per-arch auto policies (§Perf policy matrix)."""
    d, dh = cfg.d_model, cfg.head_dim
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.rwkv:
        per_layer += 5 * d * d + d * 64 + 64 * d
        per_layer += d * cfg.d_ff + cfg.d_ff * d + d * d
    else:
        if cfg.mla:
            per_layer += d * cfg.q_rank + cfg.q_rank * cfg.n_heads * (cfg.d_nope + cfg.d_rope)
            per_layer += d * (cfg.kv_rank + cfg.d_rope)
            per_layer += cfg.kv_rank * cfg.n_heads * (cfg.d_nope + cfg.d_v)
            per_layer += cfg.n_heads * cfg.d_v * d
        else:
            per_layer += d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh
            per_layer += cfg.n_heads * dh * d
        if cfg.hybrid:
            di = cfg.mamba_expand * d
            per_layer += 2 * d * di + di * (2 * cfg.ssm_state + 1) + di * d
        if cfg.family == "moe":
            e = cfg.n_experts if not active else cfg.top_k
            ff = cfg.moe_d_ff or cfg.d_ff
            per_layer += d * cfg.n_experts
            per_layer += e * (2 * d * ff + ff * d)
        else:
            per_layer += 3 * d * cfg.d_ff
    n = emb + cfg.n_layers * per_layer
    if cfg.enc_dec:
        n += cfg.n_enc_layers * (4 * d * dh * cfg.n_heads + 3 * d * cfg.d_ff)
        n += cfg.n_layers * 4 * d * dh * cfg.n_heads
    return n


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, *,
               per_lane_idx: bool = False) -> Dict[str, jax.Array]:
    """Stacked per-layer decode cache. RWKV: O(1) state. MLA: compressed
    latent. GQA: [L, B, Smax, K, Dh] keys/values.

    ``per_lane_idx=True`` gives each batch lane its own write index
    ([L, B] instead of [L]) — the continuous-batching engine's cache,
    where lanes prefill/decode at independent positions."""
    Lh = cfg.n_layers
    idx = (jnp.zeros((Lh, batch), jnp.int32) if per_lane_idx
           else jnp.zeros((Lh,), jnp.int32))
    if cfg.rwkv:
        C = cfg.d_model // cfg.n_heads
        return {
            "S": jnp.zeros((Lh, batch, cfg.n_heads, C, C), dtype),
            "x_tm": jnp.zeros((Lh, batch, cfg.d_model), dtype),
            "x_cm": jnp.zeros((Lh, batch, cfg.d_model), dtype),
        }
    if cfg.mla:
        return {
            "k": jnp.zeros((Lh, batch, max_seq, cfg.kv_rank), dtype),
            "v": jnp.zeros((Lh, batch, max_seq, cfg.d_rope), dtype),
            "idx": idx,
        }
    out = {
        "k": jnp.zeros((Lh, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((Lh, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "idx": idx,
    }
    if cfg.hybrid:
        out["h_ssm"] = jnp.zeros(
            (Lh, batch, cfg.mamba_expand * cfg.d_model, cfg.ssm_state), dtype
        )
    return out


# --------------------------------------------------------------------------
# losses / steps (jnp-only fast path)
# --------------------------------------------------------------------------

def next_token_loss(bk, params, cfg: ArchConfig, tokens, targets,
                    frontend_embeds=None, enc_embeds=None):
    logits, _ = forward(bk, params, cfg, tokens,
                        frontend_embeds=frontend_embeds,
                        enc_embeds=enc_embeds)
    logits = bk.value_of(logits)
    if frontend_embeds is not None:
        # loss only on the text positions (suffix)
        logits = logits[:, -targets.shape[1]:]
    logits = logits.astype(jnp.float32)

    # Keep the vocab dim model-sharded through the whole loss: a gather (or
    # an unconstrained one-hot) makes XLA replicate the [B,S,V] f32 logits —
    # 67 GiB per copy for the 256k-vocab archs (§Perf train iteration 3).
    def _vshard(t):
        mesh = getattr(bk, "mesh", None)
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            return t
        from jax.sharding import NamedSharding, PartitionSpec as P
        m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        if t.shape[-1] % m:
            return t
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        spec = P(dp or None, *([None] * (t.ndim - 2)), "model")
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    logits = _vshard(logits)
    onehot = _vshard(jax.nn.one_hot(targets, logits.shape[-1],
                                    dtype=logits.dtype))
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return (lse - picked).mean()
