"""Backend-generic building blocks shared by every architecture.

All functions take the arithmetic backend ``bk`` first — with
:class:`repro.core.backend.JOps` they are ordinary jnp (jit/pjit-able); with
:class:`repro.core.backend.CaaOps` they propagate rigorous CAA error bounds
(the paper's operator-overloading trick, JAX-style). Parameters arrive as
raw arrays and are wrapped via ``bk.param``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initialisers (plain numpy-free jax, used by every arch's init)
# --------------------------------------------------------------------------

def dense_init(key, n_in: int, n_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / math.sqrt(n_in))
    return jax.random.normal(key, (n_in, n_out), jnp.float32) * scale


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

import numpy as _np


def rmsnorm(bk, x, gamma, eps: float = 1e-6):
    """x * rsqrt(mean(x², -1) + eps) * γ — re-anchors ranges to O(1), the
    'activation layers recover accuracy' effect the paper highlights.

    Global insight injected for the analysis: |x_i|/√(mean(x²)+eps) ≤ √n
    always (x_i² ≤ n·mean(x²)) — IA alone pairs x_hi with 1/√eps and
    explodes; the clamp is the algebraic fact it cannot see."""
    g = bk.param(gamma)
    ms = bk.mean(bk.square(x), axis=-1, keepdims=True)
    inv = bk.rsqrt(bk.shift(ms, eps))
    y = bk.mul(bk.mul(x, inv), g)
    n = bk.shape_of(x)[-1]
    bound = (_np.sqrt(n) * 1.0000001) * jnp.abs(jnp.asarray(gamma, jnp.float64))
    return bk.clamp_range(y, -bound, bound)


def groupless_norm_bound(n: int):
    return _np.sqrt(n) * 1.0000001


def layernorm(bk, x, gamma, beta, eps: float = 1e-5):
    """Same global-insight clamp as rmsnorm: |(x−μ)/σ| ≤ √n."""
    mu = bk.mean(x, axis=-1, keepdims=True)
    xc = bk.sub(x, mu)
    var = bk.mean(bk.square(xc), axis=-1, keepdims=True)
    inv = bk.rsqrt(bk.shift(var, eps))
    y = bk.add(bk.mul(bk.mul(xc, inv), bk.param(gamma)), bk.param(beta))
    n = bk.shape_of(x)[-1]
    g64 = jnp.abs(jnp.asarray(gamma, jnp.float64))
    b64 = jnp.asarray(beta, jnp.float64)
    bound = (_np.sqrt(n) * 1.0000001) * g64
    return bk.clamp_range(y, b64 - bound, b64 + bound)


# --------------------------------------------------------------------------
# embeddings / heads
# --------------------------------------------------------------------------

def embed(bk, table, ids):
    """Exact gather of format-stored rows."""
    return bk.take(bk.param(table), ids, axis=0)


def logits_head(bk, x, table, softcap: Optional[float] = None):
    """Final projection (tied or untied); optional gemma-style softcap —
    the paper's tanh rule (×2.63) is load-bearing here."""
    y = bk.einsum("bsd,vd->bsv", x, bk.param(table))
    if softcap:
        y = bk.softcap(y, softcap)
    return y


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_gated(bk, x, w_gate, w_up, w_down, act: str = "silu"):
    """LLaMA-style gated MLP: down( act(x@Wg) * (x@Wu) )."""
    g = bk.matmul(x, bk.param(w_gate))
    u = bk.matmul(x, bk.param(w_up))
    a = getattr(bk, act)(g)
    return bk.matmul(bk.mul(a, u), bk.param(w_down))


def mlp_plain(bk, x, w_in, b_in, w_out, b_out, act: str = "gelu"):
    h = bk.add(bk.matmul(x, bk.param(w_in)), bk.param(b_in))
    h = getattr(bk, act)(h)
    return bk.add(bk.matmul(h, bk.param(w_out)), bk.param(b_out))


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_tables(positions, d_head: int, theta: float = 10000.0):
    """cos/sin tables for the given positions: [S, d_head//2] each."""
    half = d_head // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(bk, x, cos, sin):
    """x: [B, S, H, Dh]; tables [S, Dh/2] — or [B, S, Dh/2] for the ragged
    decode path (per-lane absolute positions). Tables enter as stored
    params (rounded transcendental constants) for analysis honesty."""
    dh = bk.shape_of(x)[-1]
    half = dh // 2
    x1 = bk.slice(x, (Ellipsis, slice(0, half)))
    x2 = bk.slice(x, (Ellipsis, slice(half, dh)))
    if getattr(cos, "ndim", 2) == 3:        # per-lane tables [B, S, Dh/2]
        c = bk.param(cos[:, :, None, :])
        s = bk.param(sin[:, :, None, :])
    else:
        c = bk.param(cos[None, :, None, :])
        s = bk.param(sin[None, :, None, :])
    r1 = bk.sub(bk.mul(x1, c), bk.mul(x2, s))
    r2 = bk.add(bk.mul(x2, c), bk.mul(x1, s))
    return bk.concat([r1, r2], axis=-1)


# --------------------------------------------------------------------------
# masks (exact integer logic — no FP error involved)
# --------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int, q_offset: int = 0,
                window: Optional[int] = None):
    """Boolean [q_len, kv_len]: True = attendable. ``window`` gives sliding-
    window (SWA) masking; q_offset places queries at absolute positions for
    decode."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    ok = k_pos <= q_pos
    if window is not None:
        ok = ok & (k_pos > q_pos - window)
    return ok


def lane_causal_mask(q_len: int, kv_len: int, q_offsets,
                     window: Optional[int] = None):
    """Per-lane boolean [B, q_len, kv_len] for the ragged decode path:
    lane b's queries sit at absolute positions ``q_offsets[b] + arange``.
    Exact integer logic, same attendability rule as :func:`causal_mask`."""
    q_pos = q_offsets[:, None, None] + jnp.arange(q_len)[None, :, None]
    k_pos = jnp.arange(kv_len)[None, None, :]
    ok = k_pos <= q_pos
    if window is not None:
        ok = ok & (k_pos > q_pos - window)
    return ok


NEG_BIG = -1e9  # mask value: exact constant, exp(-1e9)=0 under IA too
