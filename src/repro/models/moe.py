"""Mixture-of-Experts MLP: router + top-k dispatch.

Two execution modes:
  dense     — every expert computed for every token, combined by gates.
              Exact, backend-generic (CAA-analysable), O(E) flops: used for
              analysis and smoke tests.
  dropping  — capacity-bounded one-hot dispatch einsums under a scan over
              token chunks (keeps the [Tc, E, C] dispatch tensor small);
              the production path; expert dim shards over the "model" mesh
              axis (expert parallelism → all-to-all under SPMD).

The router's top-k is FP-dependent control flow: under CAA the route is
fixed from reference values and the decision margin recorded (the paper's
argmax treatment, applied to routing — see backend.CaaOps.top_k_mask).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L


def init_moe(key, d: int, d_ff: int, n_experts: int):
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_router": L.dense_init(ks[0], d, n_experts),
        "w_gate": jax.random.normal(ks[1], (n_experts, d, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (n_experts, d, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (n_experts, d_ff, d), jnp.float32) * s_out,
    }


def moe_mlp(
    bk, x, p, *,
    n_experts: int, top_k: int,
    act: str = "silu",
    capacity_factor: float = 1.25,
    chunk_tokens: int = 4096,
    mode: Optional[str] = None,
):
    """x: [B, S, d] → [B, S, d].

    Mode selection: analysis → dense; a mesh with a "model" axis that
    divides n_experts → expert-parallel shard_map (the production path);
    otherwise chunked capacity dispatch under pjit.
    """
    if mode is None:
        if bk.is_analysis:
            mode = "dense"
        elif _ep_mesh(bk, n_experts) is not None:
            mode = "ep_shard_map"
        else:
            mode = "dropping"
    B, S, d = bk.shape_of(x)

    if mode == "ep_shard_map":
        y = _ep_experts(bk, bk.value_of(x), p, n_experts, top_k, act,
                        capacity_factor, chunk_tokens)
        return bk.input(y)

    xt = bk.reshape(x, (B * S, d))
    logits = bk.matmul(xt, bk.param(p["w_router"]))
    probs = bk.softmax(logits, axis=-1)
    mask = bk.top_k_mask(probs, top_k)                      # [T,E] exact 0/1
    gates = bk.mul(probs, bk.input(mask) if bk.is_analysis else mask)
    denom = bk.sum(gates, axis=-1, keepdims=True)
    gates = bk.div(gates, denom)                            # renormalised

    if mode == "dense":
        y = _dense_experts(bk, xt, gates, p, act)
    else:
        y = _dropping_experts(
            bk, xt, bk.value_of(gates), p, n_experts, top_k, act,
            capacity_factor, chunk_tokens,
        )
        y = bk.input(y)
    return bk.reshape(y, (B, S, d))


def _ep_mesh(bk, n_experts: int):
    mesh = getattr(bk, "mesh", None)
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if m > 1 and n_experts % m == 0:
        return mesh
    return None


def _ep_experts(bk, x, p, n_experts, top_k, act, capacity_factor,
                chunk_tokens):
    """Expert parallelism via shard_map (the production MoE, DESIGN.md §5).

    Tokens are sharded over the DP axes and *replicated* across "model";
    experts are sharded over "model". Every model-rank selects, from its
    replicated token block, the tokens routed to ITS local experts —
    dispatch costs zero inter-chip traffic — runs the local expert GEMMs,
    and the gate-weighted partial outputs are combined with ONE activation-
    sized psum over "model" per layer. Collectives per layer: psum of
    [T_local, d] — versus the pjit chunk-scan path whose global dispatch
    einsums forced XLA into parameter/token-sized all-gathers.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = bk.mesh
    B, S, d = x.shape
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_loc = n_experts // m_size

    wr = bk.param(p["w_router"])
    wg = bk.param(p["w_gate"])
    wu = bk.param(p["w_up"])
    wd = bk.param(p["w_down"])

    def local(xb, wrb, wgb, wub, wdb):
        # xb: [B_loc, S, d] (replicated across model); w*b: [e_loc, ...]
        Bl = xb.shape[0]
        xt = xb.reshape(Bl * S, d)
        logits = xt @ wrb                                  # full router [T,E]
        probs = jax.nn.softmax(logits, axis=-1)
        _, idx = jax.lax.top_k(probs, top_k)
        mask = jax.nn.one_hot(idx, n_experts, dtype=xt.dtype).sum(-2)
        gates = probs * mask
        gates = gates / gates.sum(-1, keepdims=True)
        # this rank's expert slice
        rank = jax.lax.axis_index("model")
        lo = rank * e_loc
        gsel = jax.lax.dynamic_slice_in_dim(gates, lo, e_loc, axis=1)
        msel = jax.lax.dynamic_slice_in_dim(mask, lo, e_loc, axis=1)
        T = xt.shape[0]
        Tc = min(chunk_tokens, T)
        n_chunks = (T + Tc - 1) // Tc
        C = max(1, int(Tc * top_k / n_experts * capacity_factor))

        def one_chunk(_, args):
            xc, gc, mc = args                              # [Tc,d],[Tc,e_loc]
            sel = mc > 0
            pos = jnp.cumsum(sel.astype(jnp.int32), axis=0) * sel - 1
            keep = sel & (pos < C)
            disp = jax.nn.one_hot(jnp.where(keep, pos, -1), C, dtype=xc.dtype)
            disp = disp * keep[..., None].astype(xc.dtype)   # [Tc,e_loc,C]
            xe = jnp.einsum("tec,td->ecd", disp, xc)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wgb))                 * jnp.einsum("ecd,edf->ecf", xe, wub)
            ye = jnp.einsum("ecf,efd->ecd", h, wdb)
            comb = disp * gc[..., None].astype(xc.dtype)
            return None, jnp.einsum("tec,ecd->td", comb, ye)

        pad = n_chunks * Tc - T
        xt_p = jnp.pad(xt, ((0, pad), (0, 0))) if pad else xt
        g_p = jnp.pad(gsel, ((0, pad), (0, 0))) if pad else gsel
        m_p = jnp.pad(msel, ((0, pad), (0, 0))) if pad else msel
        _, ys = jax.lax.scan(
            one_chunk, None,
            (xt_p.reshape(n_chunks, Tc, d),
             g_p.reshape(n_chunks, Tc, e_loc),
             m_p.reshape(n_chunks, Tc, e_loc)))
        y = ys.reshape(-1, d)[:T]
        y = jax.lax.psum(y, "model")                       # combine experts
        return y.reshape(Bl, S, d)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp_axes or None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dp_axes or None, None, None),
    )
    return fn(x, wr, wg, wu, wd)


def _dense_experts(bk, xt, gates, p, act):
    """All experts on all tokens; gate-weighted combine. CAA-friendly."""
    h_g = bk.einsum("td,edf->tef", xt, bk.param(p["w_gate"]))
    h_u = bk.einsum("td,edf->tef", xt, bk.param(p["w_up"]))
    h = bk.mul(getattr(bk, act)(h_g), h_u)
    y_e = bk.einsum("tef,efd->ted", h, bk.param(p["w_down"]))
    return bk.einsum("ted,te->td", y_e, gates)


def _dropping_experts(bk, xt, gates, p, n_experts, top_k, act,
                      capacity_factor, chunk_tokens):
    """Capacity dispatch in token chunks (jnp path; runs under JOps only).

    Per chunk of Tc tokens: capacity C = ceil(Tc·top_k/E · cf); tokens beyond
    an expert's capacity are dropped (standard Switch semantics). Dispatch/
    combine are one-hot einsums — they lower to all-to-all when the expert
    dim is sharded.
    """
    xt = bk.value_of(xt)
    w_gate = bk.param(p["w_gate"])
    w_up = bk.param(p["w_up"])
    w_down = bk.param(p["w_down"])
    T, d = xt.shape
    E = n_experts
    Tc = min(chunk_tokens, T)
    n_chunks = (T + Tc - 1) // Tc
    pad = n_chunks * Tc - T
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0)))
    C = max(1, math.ceil(Tc * top_k / E * capacity_factor))

    xs = xt.reshape(n_chunks, Tc, d)
    gs = gates.reshape(n_chunks, Tc, E)

    def one_chunk(_, xg):
        xc, gc = xg                                  # [Tc,d], [Tc,E]
        sel = gc > 0
        pos = jnp.cumsum(sel.astype(jnp.int32), axis=0) * sel - 1
        keep = sel & (pos < C)
        disp = jax.nn.one_hot(jnp.where(keep, pos, -1), C, dtype=xc.dtype)
        disp = disp * keep[..., None].astype(xc.dtype)       # [Tc,E,C]
        xe = jnp.einsum("tec,td->ecd", disp, xc)
        hg = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        hu = jnp.einsum("ecd,edf->ecf", xe, w_up)
        h = getattr(jax.nn, "silu" if act == "silu" else act)(hg) * hu
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)
        comb = disp * gc[..., None].astype(xc.dtype)
        yc = jnp.einsum("tec,ecd->td", comb, ye)
        return None, yc

    _, ys = jax.lax.scan(one_chunk, None, (xs, gs))
    y = ys.reshape(n_chunks * Tc, d)
    return y[:T] if pad else y


def aux_load_balance_loss(gates_probs: jax.Array, mask: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    density = mask.mean(axis=0)                 # fraction routed per expert
    router_prob = gates_probs.mean(axis=0)
    return n_experts * jnp.sum(density * router_prob)
