"""Model zoo: backend-generic layers and all assigned architectures."""
from . import attention, layers, moe, paper_models, ssm, transformer
from .transformer import ArchConfig, forward, init_cache, init_params, next_token_loss

__all__ = [
    "attention", "layers", "moe", "paper_models", "ssm", "transformer",
    "ArchConfig", "forward", "init_cache", "init_params", "next_token_loss",
]
