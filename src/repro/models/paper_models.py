"""The paper's own three experiment models (Section V / Table I).

  Digits    — MNIST-style classifier: three Dense, two ReLU, one Softmax
              (≈0.7M parameters at the default widths).
  ConvNet   — a small convolutional classifier standing in for the paper's
              MobileNet study (Conv → ReLU → Pool → Dense → Softmax); conv is
              implemented as patch-extraction + matmul so the rigorous
              trajectory dot-product rule applies verbatim.
  Pendulum  — the Lyapunov-function approximator from [19]: two Dense layers
              with two tanh activations, 2-D input on [-6, 6]².

All are backend-generic: run them under JOps to infer, under CaaOps to get
Table-I-style rigorous error bounds.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers as L


# --------------------------------------------------------------------------
# Digits
# --------------------------------------------------------------------------

def init_digits(key, d_in: int = 784, h1: int = 700, h2: int = 256,
                n_classes: int = 10) -> Dict:
    """≈0.7M params at defaults (784·700 + 700·256 + 256·10 ≈ 0.73M)."""
    ks = jax.random.split(key, 3)
    return {
        "w1": L.dense_init(ks[0], d_in, h1), "b1": jnp.zeros((h1,), jnp.float32),
        "w2": L.dense_init(ks[1], h1, h2), "b2": jnp.zeros((h2,), jnp.float32),
        "w3": L.dense_init(ks[2], h2, n_classes),
        "b3": jnp.zeros((n_classes,), jnp.float32),
    }


def digits_forward(bk, params, x):
    """x: [..., 784] in [0,1]. Returns softmax probabilities.

    Each block runs inside a named backend scope ("dense1" … "softmax") —
    the addressable unit for sensitivity attribution and per-layer
    mixed-precision certificates (record() calls stay outside the scopes so
    trace names are unchanged)."""
    with bk.scope("dense1"):
        h = bk.add(bk.matmul(bk.input(x) if not hasattr(x, "val") else x,
                             bk.param(params["w1"])), bk.param(params["b1"]))
        h = bk.relu(h)
    h = bk.record("dense1", h)
    with bk.scope("dense2"):
        h = bk.add(bk.matmul(h, bk.param(params["w2"])), bk.param(params["b2"]))
        h = bk.relu(h)
    h = bk.record("dense2", h)
    with bk.scope("dense3"):
        o = bk.add(bk.matmul(h, bk.param(params["w3"])), bk.param(params["b3"]))
    o = bk.record("dense3", o)
    with bk.scope("softmax"):
        p = bk.softmax(o, axis=-1)
    return bk.record("softmax", p)


def digits_logits(bk, params, x):
    with bk.scope("dense1"):
        h = bk.add(bk.matmul(bk.input(x) if not hasattr(x, "val") else x,
                             bk.param(params["w1"])), bk.param(params["b1"]))
        h = bk.relu(h)
    with bk.scope("dense2"):
        h = bk.add(bk.matmul(h, bk.param(params["w2"])), bk.param(params["b2"]))
        h = bk.relu(h)
    with bk.scope("dense3"):
        return bk.add(bk.matmul(h, bk.param(params["w3"])), bk.param(params["b3"]))


# --------------------------------------------------------------------------
# ConvNet (the MobileNet-class stand-in)
# --------------------------------------------------------------------------

def init_convnet(key, img: int = 28, c_in: int = 1, c1: int = 16,
                 c2: int = 32, n_classes: int = 10, ksz: int = 3) -> Dict:
    ks = jax.random.split(key, 4)
    side = img // 4  # two stride-2 pools
    return {
        "k1": jax.random.normal(ks[0], (ksz * ksz * c_in, c1), jnp.float32)
        * (ksz * ksz * c_in) ** -0.5,
        "bk1": jnp.zeros((c1,), jnp.float32),
        "k2": jax.random.normal(ks[1], (ksz * ksz * c1, c2), jnp.float32)
        * (ksz * ksz * c1) ** -0.5,
        "bk2": jnp.zeros((c2,), jnp.float32),
        "wd": L.dense_init(ks[2], side * side * c2, n_classes),
        "bd": jnp.zeros((n_classes,), jnp.float32),
        "meta": {"img": img, "c_in": c_in, "ksz": ksz},
    }


def _extract_patches(bk, x, img: int, c: int, ksz: int):
    """[B, img, img, c] → [B, img, img, ksz·ksz·c] (SAME padding), as an
    exact gather so conv == patches @ kernel-matrix (the paper's 'basic
    arithmetic operation in convolution layers is again the dot product')."""
    pad = ksz // 2
    xv = x
    B = bk.shape_of(x)[0]
    idx = jnp.arange(img)
    rows = jnp.clip(idx[:, None] + jnp.arange(-pad, pad + 1)[None, :], 0, img - 1)
    # gather rows then cols; zero-padding emulated by masking
    valid_r = (idx[:, None] + jnp.arange(-pad, pad + 1)[None, :] >= 0) & (
        idx[:, None] + jnp.arange(-pad, pad + 1)[None, :] <= img - 1
    )
    patches = []
    for dr in range(ksz):
        row_idx = rows[:, dr]
        xr = bk.take(x, row_idx, axis=1)
        mr = valid_r[:, dr]
        for dc in range(ksz):
            col_idx = rows[:, dc]
            xc = bk.take(xr, col_idx, axis=2)
            mc = valid_r[:, dc]
            m = (mr[:, None] & mc[None, :])[None, :, :, None]
            zero = bk.const(jnp.zeros(()))
            xc = bk.where(m, xc, bk.broadcast_to(zero, bk.shape_of(xc)))
            patches.append(xc)
    return bk.concat(patches, axis=-1)


def convnet_forward(bk, params, x):
    """x: [B, img, img, c_in] in [0,1] → probabilities [B, 10]."""
    meta = params["meta"]
    img, c_in, ksz = meta["img"], meta["c_in"], meta["ksz"]
    x = bk.input(x) if not hasattr(x, "val") else x

    p = _extract_patches(bk, x, img, c_in, ksz)
    h = bk.add(bk.matmul(p, bk.param(params["k1"])), bk.param(params["bk1"]))
    h = bk.relu(bk.record("conv1", h))
    h = _maxpool2(bk, h)

    c1 = bk.shape_of(h)[-1]
    p2 = _extract_patches(bk, h, img // 2, c1, ksz)
    h = bk.add(bk.matmul(p2, bk.param(params["k2"])), bk.param(params["bk2"]))
    h = bk.relu(bk.record("conv2", h))
    h = _maxpool2(bk, h)

    B = bk.shape_of(h)[0]
    side = img // 4
    c2 = bk.shape_of(h)[-1]
    h = bk.reshape(h, (B, side * side * c2))
    o = bk.add(bk.matmul(h, bk.param(params["wd"])), bk.param(params["bd"]))
    return bk.record("softmax", bk.softmax(o, axis=-1))


def _maxpool2(bk, x):
    """2×2 max pool, stride 2 — pure selection, error-free in CAA."""
    B, H, W, C = bk.shape_of(x)
    a = bk.slice(x, (slice(None), slice(0, H, 2), slice(0, W, 2)))
    b = bk.slice(x, (slice(None), slice(1, H, 2), slice(0, W, 2)))
    c = bk.slice(x, (slice(None), slice(0, H, 2), slice(1, W, 2)))
    d = bk.slice(x, (slice(None), slice(1, H, 2), slice(1, W, 2)))
    return bk.maximum(bk.maximum(a, b), bk.maximum(c, d))


# --------------------------------------------------------------------------
# Pendulum (Lyapunov)
# --------------------------------------------------------------------------

def init_pendulum(key, h: int = 64) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "w1": L.dense_init(ks[0], 2, h), "b1": jnp.zeros((h,), jnp.float32),
        "w2": L.dense_init(ks[1], h, h), "b2": jnp.zeros((h,), jnp.float32),
        "w3": L.dense_init(ks[2], h, 1), "b3": jnp.zeros((1,), jnp.float32),
    }


def pendulum_forward(bk, params, x):
    """x: [..., 2] on [-6, 6]² → scalar Lyapunov value. The output range
    contains 0, so (exactly as the paper reports) no relative bound exists —
    only the absolute one. Blocks are scoped like digits_forward for
    sensitivity/mixed-precision addressing."""
    with bk.scope("dense1"):
        h = bk.add(bk.matmul(bk.input(x) if not hasattr(x, "val") else x,
                             bk.param(params["w1"])), bk.param(params["b1"]))
    h = bk.record("dense1", h)
    with bk.scope("dense1"):
        h = bk.tanh(h)
    with bk.scope("dense2"):
        h = bk.add(bk.matmul(h, bk.param(params["w2"])), bk.param(params["b2"]))
    h = bk.record("dense2", h)
    with bk.scope("dense2"):
        h = bk.tanh(h)
    with bk.scope("dense3"):
        return bk.add(bk.matmul(h, bk.param(params["w3"])), bk.param(params["b3"]))
