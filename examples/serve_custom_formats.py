"""End-to-end custom-format serving: certify (k, emin, emax) per scope,
then serve digits through the certified formats — with receipts.

The full schema-v3 vertical in one script:

  1. train the paper's Digits classifier (tiny, seeded);
  2. certify per-scope FULL formats — mixed mantissa map + IA-range-proven
     exponent ranges with underflow folded into the bounds
     (``repro.certify --formats`` under the hood), persisted to a store;
  3. serve a batch through ``FormatQuantJOps`` (every matmul rounded into
     its scope's certified format) with (δ̄, ε̄, format) error bars;
  4. cross-check one layer's GEMM against the scalar-prefetch Pallas
     kernel, bit for bit, in interpret mode.

Run:  PYTHONPATH=src python examples/serve_custom_formats.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import certify as C
from repro.core import formats as F
from repro.data import synthetic_digits
from repro.launch.serve import FormatQuantJOps
from repro.models import paper_models as PM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=300)
    ap.add_argument("--h1", type=int, default=32)
    ap.add_argument("--h2", type=int, default=16)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--store", default=None,
                    help="certificate store dir (default: no persistence)")
    args = ap.parse_args()

    imgs, labels = synthetic_digits.make_dataset(args.samples, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0), h1=args.h1, h2=args.h2)
    from repro.certify.__main__ import _train_digits
    params = _train_digits(params, imgs, labels, steps=120)
    los, his = [], []
    for c in range(10):
        m = imgs[labels == c].mean(0)
        los.append(np.clip(m - 0.02, 0.0, 1.0))
        his.append(np.clip(m + 0.02, 0.0, 1.0))

    store = None if args.store is None else C.CertificateStore(args.store)
    t0 = time.perf_counter()
    cs = C.certify(PM.digits_forward, params, los, his, p_star=0.6,
                   model_id=f"digits/h{args.h1}x{args.h2}", store=store,
                   k_max=24, mixed=True, formats=True)
    print(f"certified in {time.perf_counter() - t0:.1f}s"
          + (" (store hit)" if cs.meta.get("from_store") else ""))
    print(cs.summary())

    sm = cs.serving_layer_format
    if sm is None:
        raise SystemExit("no jointly-certified format map — widen k_max")
    fm = cs.meta.get("formats", {})
    if fm.get("applied"):
        print(f"\nbits/value: baseline {fm['baseline_bits']} → "
              f"{fm['mean_bits_flop_weighted']:.2f} FLOP-weighted "
              f"(saves {fm['savings_bits_flop_weighted']:.2f})")

    # -- serve through the certified formats -------------------------------
    bk = FormatQuantJOps(sm, None)
    x = jnp.asarray(imgs[:args.batch].astype(np.float32))
    serve = jax.jit(lambda p, xx: PM.digits_forward(bk, p, xx))
    probs = jax.block_until_ready(serve(params, x))
    t0 = time.perf_counter()
    probs = jax.block_until_ready(serve(params, x))
    t_serve = time.perf_counter() - t0
    pred = np.asarray(jnp.argmax(probs, -1))
    acc = float((pred == labels[:args.batch]).mean())
    print(f"\nserved {args.batch} requests through certified formats in "
          f"{t_serve*1e3:.2f} ms (acc {acc:.3f})")
    bars = cs.error_bars()
    print(f"response error bars: dbar={bars['dbar_u']:.4g}u "
          f"ebar={bars['ebar_u']:.4g}u k={bars['k']}")

    # -- scalar-prefetch kernel, bitwise -----------------------------------
    from repro.kernels.quant_matmul import (quant_matmul_format,
                                            quant_matmul_format_ref)
    fmt = F.from_dict(sm["dense1"])
    triple = jnp.asarray([fmt.k, fmt.emax, fmt.emin], jnp.int32)
    xs = x[: min(8, args.batch)]
    w1 = jnp.asarray(np.asarray(params["w1"], np.float32))
    ker = quant_matmul_format(xs, w1, triple, block_m=int(xs.shape[0]),
                              block_n=args.h1, block_k=784, interpret=True)
    ref = quant_matmul_format_ref(xs, w1, triple)
    assert bool(jnp.array_equal(ker, ref)), "kernel/eager drift!"
    print(f"Pallas scalar-prefetch kernel == eager emulation (bitwise) for "
          f"dense1's {fmt.describe()}")


if __name__ == "__main__":
    main()
