"""Serve an LM architecture under a scan-native mixed-precision certificate.

The pipeline end-to-end, on a reduced registered arch:

  1. **Certify** — ``repro.certify.certify_lm(mixed=True)`` runs the
     layer-stacked CAA analysis: one compiled probe ladder (the layer
     stack is ONE ``lax.scan`` whose body gathers per-layer round-scale
     lanes by layer index) searches the uniform k, ranks layer
     sensitivities, and descends a rigorous ``{layer{i}|head: k}`` map,
     eagerly re-confirmed on the unrolled per-layer reference before it
     persists (schema v3, content-addressed store).
  2. **Serve** — ``launch/serve.py`` picks the map up automatically:
     matmuls inside each mapped scope run at that scope's k through the
     scanned traced-k quantisation path (one compilation for all layers),
     and every response carries the certified (δ̄, ε̄, k) error bars.
  3. **Differential** — the scanned mixed serving path is checked
     bit-for-bit against an eager per-layer reference that applies each
     layer's static k in a Python unroll (both jitted — the same XLA
     program per layer).

Run:  PYTHONPATH=src python examples/serve_certified_lm.py
      PYTHONPATH=src python examples/serve_certified_lm.py --formats \
          --decode-steps 8

The first run pays the analysis; re-runs load the certificate from the
store (watch the fetch time collapse).
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import (MixedQuantJOps, ServeConfig,
                                UnrolledLayerLoop, apply_certificates,
                                build_serve_steps, make_responses)
from repro.models import transformer as T


class UnrolledMixedQuantJOps(UnrolledLayerLoop, MixedQuantJOps):
    """Eager per-layer reference: Python loop, static string-scope k."""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--max-layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prefill-len", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--formats", action="store_true",
                    help="also synthesize per-scope custom (k, emin, emax) "
                         "formats")
    ap.add_argument("--certificates", default=None, metavar="STORE_DIR",
                    help="certificate store (default: a temp dir)")
    args = ap.parse_args()

    smoke = configs.get(args.arch).SMOKE
    cfg = dataclasses.replace(
        smoke, n_layers=min(args.max_layers, smoke.n_layers))
    store_dir = args.certificates or tempfile.mkdtemp(prefix="lmcerts_")
    sc = ServeConfig(arch=args.arch, batch=args.batch,
                     max_seq=args.prefill_len + args.decode_steps + 1,
                     prefill_len=args.prefill_len,
                     certificates=store_dir)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    t0 = time.perf_counter()
    sc, certset = apply_certificates(
        sc, cfg, params, mixed=True, formats=args.formats, k_max=53,
        seq=args.prefill_len, batch=1)
    t_cert = time.perf_counter() - t0
    src = ("store hit — no re-analysis" if certset.meta.get("from_store")
           else "cold scan-native analysis — persisted for next time")
    print(f"certificate fetch: {t_cert:.2f}s ({src})")
    print(f"  uniform k={sc.precision_k}, mixed map={sc.precision_layer_k}")
    mx = certset.meta.get("mixed")
    if mx and mx.get("applied"):
        print(f"  FLOP-weighted mean k={mx['mean_k_flop_weighted']:.2f} "
              f"→ {mx['mean_bits_flop_weighted']:.2f} bits/value "
              f"(binary32 ships 32)")

    mesh = make_host_mesh()
    with mesh:
        prefill, decode, _ = build_serve_steps(cfg, sc, mesh)
        cache = T.init_cache(cfg, sc.batch, sc.max_seq, jnp.float32)
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab, (sc.batch, sc.prefill_len)))}
        logits, cache = prefill(params, cache, batch)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        toks = [tok]
        for i in range(args.decode_steps):
            db = {"tokens": tok[:, None],
                  "pos": jnp.asarray(sc.prefill_len + i, jnp.int32)}
            tok, cache = decode(params, cache, db)
            toks.append(tok)
        out = jnp.stack(toks, axis=1)
        responses = make_responses(out, certset)
        print(f"served {sc.batch} seqs × {args.decode_steps} tokens; "
              f"response[0]: {responses[0]['tokens'][:6]}…")
        print(f"  error bars: dbar={responses[0]['certificate']['dbar_u']:.4g}u "
              f"at k={responses[0]['certificate']['k']}")

    # bit-for-bit differential: scanned mixed serving vs the eager
    # per-layer reference (both jitted — identical per-layer XLA programs)
    if sc.precision_layer_k:
        lk, dk = sc.precision_layer_k, sc.precision_k
        f_scan = jax.jit(lambda p, t: T.forward(
            MixedQuantJOps(lk, dk), p, cfg, t)[0])
        f_ref = jax.jit(lambda p, t: T.forward(
            UnrolledMixedQuantJOps(lk, dk), p, cfg, t)[0])
        a, b = f_scan(params, batch["tokens"]), f_ref(params, batch["tokens"])
        assert bool(jnp.array_equal(a, b)), "scan vs unrolled mismatch!"
        print("differential: scanned mixed serving == eager per-layer "
              "reference, bit for bit")


if __name__ == "__main__":
    main()
