"""Rigorous precision analysis of a transformer LM (reduced config).

Runs the CAA engine through a full GQA transformer (the same model code the
512-chip runtime executes) and reports:
  * per-layer error growth (the trace),
  * Table-I-style actual-error of an emulated k-bit run,
  * MoE router decision margins (the routing-flip analogue of the paper's
    top-1 analysis) for a mixtral-family model.

Run:  PYTHONPATH=src python examples/lm_precision_analysis.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import caa
from repro.core.backend import CaaOps
from repro.models import transformer as T


def analyse(arch: str, k: int = 12):
    cfg = configs.get(arch).SMOKE
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ccfg = caa.CaaConfig(u_max=2.0 ** (1 - k), emulate_k=k)
    bk = CaaOps(ccfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    logits, _ = T.forward(bk, params, cfg, tokens)
    a_abs, a_rel = caa.actual_error_in_u(logits, ccfg.u_max)

    print(f"\n=== {arch} (reduced config), emulated k={k}")
    print(f"  logits: actual abs err ≤ {float(jnp.max(a_abs)):.4g}u "
          f"(u = 2^{1-k})")
    print(f"  per-layer trace ({len(bk.trace)} records):")
    for r in bk.trace[:6]:
        print(f"    {r.name:28s} kind={r.kind:8s} |range|≤{r.out_mag:9.3g} "
              f"δ̄={r.max_dbar:9.3g}u")
    routers = [r for r in bk.trace if r.kind == "router"]
    for r in routers[:4]:
        print(f"    router {r.name}: min margin {r.extra['min_margin']:.4f} "
              f"→ routing flip-safe for u ≤ {r.extra['flip_safe_if_u_le']:.3g}")


def main():
    analyse("qwen2_7b")
    analyse("mixtral_8x22b")   # includes router-margin records
    analyse("rwkv6_1p6b")      # recurrence analysed by the fixpoint rule


if __name__ == "__main__":
    main()
