"""Pendulum (paper §V-c): rigorous absolute error bound for a Lyapunov-
function network, ready to feed a formal verification pipeline.

The paper: two Dense + two tanh, input on [-6,6]²; their tool emits an
absolute bound in ~100 ms and no relative bound (the output range contains
zero). We reproduce exactly that, and additionally emit the bound as a
function of precision k — the certificate a verifier like [19] consumes.

Run:  PYTHONPATH=src python examples/pendulum_certificate.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa
from repro.core.backend import CaaOps, JOps
from repro.models import paper_models as PM


def train_lyapunov(params, steps=800, lr=0.05):
    """Fit V(θ,ω) ≈ a quadratic Lyapunov candidate on [-6,6]² (as in the
    paper's source [19]); trained weights are small and smooth, which is
    what makes a ~1u absolute bound attainable."""
    bk = JOps()

    def target(x):
        th, om = x[..., 0], x[..., 1]
        return 0.05 * (th * th + om * om + th * om)

    def loss_fn(p, x):
        v = PM.pendulum_forward(bk, p, x)[..., 0]
        return jnp.mean((v - target(x)) ** 2)

    @jax.jit
    def step(p, x):
        l, g = jax.value_and_grad(loss_fn)(p, x)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        x = jnp.asarray(np.random.RandomState(i).uniform(-6, 6, (256, 2)))
        params, _ = step(params, x)
    return params


def main():
    # width 8: [19] does not state its width; the interval-input bound
    # scales ~linearly with it (64 -> ~1.8e3 u, 8 -> the paper's regime)
    params = PM.init_pendulum(jax.random.PRNGKey(2), h=8)
    params = train_lyapunov(params)

    print("=== Pendulum Lyapunov network (trained), input range [-6, 6]² ===")
    cfg = caa.CaaConfig(u_max=2**-7)

    @jax.jit
    def analyse(lo, hi):
        out = PM.pendulum_forward(CaaOps(cfg), params, caa.from_range(lo, hi))
        return out
    lo6, hi6 = np.full(2, -6.0), np.full(2, 6.0)
    out = analyse(lo6, hi6)  # compile
    jax.block_until_ready(out.dbar)
    t0 = time.perf_counter()
    out = analyse(lo6, hi6)
    jax.block_until_ready(out.dbar)
    dt = time.perf_counter() - t0
    d, e = caa.worst(out)
    print(f"absolute error bound: {d:.4g} u  (paper: 1.7u; {dt*1e3:.0f} ms, "
          f"paper: 100 ms)")
    print(f"relative bound exists: {np.isfinite(e)} "
          "(paper: no — output interval contains zero)")
    print(f"output range: [{float(out.exact.lo[0]):.4g}, "
          f"{float(out.exact.hi[0]):.4g}]")

    print("\ncertificate |V̂(x) − V(x)| ≤ δ(k) for the verifier:")
    for k in (8, 11, 16, 24):
        c = caa.CaaConfig(u_max=2.0 ** (1 - k))
        o = PM.pendulum_forward(CaaOps(c), params,
                                caa.from_range(lo6, hi6))
        dk, _ = caa.worst(o)
        print(f"  k={k:2d}: δ = {dk * 2.0 ** (1 - k):.3e}")


if __name__ == "__main__":
    main()
