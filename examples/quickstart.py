"""Quickstart — the paper's full workflow in one script.

1. Train the paper's Digits classifier (synthetic glyph MNIST stand-in).
2. Run the CAA analysis (Table-I semantics): rigorous abs/rel error of the
   emulated k=8 run + the parametric required-k decision for p* = 0.60.
3. Serve at the certified precision and verify that every certified
   prediction matches the exact model — the paper's headline claim.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import caa, precision
from repro.core.backend import CaaOps, JOps
from repro.data import synthetic_digits
from repro.models import paper_models as PM


def train(params, imgs, labels, steps=400, lr=0.2):
    bk = JOps()

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(PM.digits_logits(bk, p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), l

    for i in range(steps):
        idx = np.random.RandomState(i).choice(imgs.shape[0], 64)
        params, l = step(params, jnp.asarray(imgs[idx]),
                         jnp.asarray(labels[idx]))
    return params


def main():
    print("=== 1. train Digits (paper: 0.7M params, 3 Dense + 2 ReLU + softmax)")
    imgs, labels = synthetic_digits.make_dataset(800, seed=0)
    params = PM.init_digits(jax.random.PRNGKey(0))
    n = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    params = train(params, imgs, labels)
    bk = JOps()
    acc = float((jnp.argmax(PM.digits_logits(bk, params, jnp.asarray(imgs)), -1)
                 == jnp.asarray(labels)).mean())
    print(f"    {n/1e6:.2f}M params, train accuracy {acc:.1%}")

    print("\n=== 2. CAA analysis at k=8 (Table-I semantics)")
    x = imgs[0].astype(np.float64)
    cfg = caa.CaaConfig(u_max=2**-7, emulate_k=8)

    @jax.jit
    def analyse(xv):
        probs = PM.digits_forward(CaaOps(cfg), params, caa.weight(xv, cfg))
        return probs, caa.actual_error_in_u(probs, 2**-7)

    probs, (a_abs, a_rel) = analyse(x)        # compile
    jax.block_until_ready(a_abs)
    t0 = time.perf_counter()
    probs, (a_abs, a_rel) = analyse(x)
    jax.block_until_ready(a_abs)
    dt = time.perf_counter() - t0
    print(f"    max abs error {float(jnp.max(a_abs)):.3g}u, "
          f"max rel {float(jnp.max(jnp.where(jnp.isfinite(a_rel), a_rel, 0))):.3g}u "
          f"(paper: 1.1u / 3.4u), analysis {dt*1e3:.0f} ms "
          f"(paper: 12 s/class)")

    def bounds_at(u):
        c = caa.CaaConfig(u_max=u)
        out = PM.digits_forward(CaaOps(c), params, caa.weight(x, c))
        return caa.worst(out)

    decision = precision.decide_iterative(bounds_at, p_star=0.60)
    print("    " + decision.explain())

    print("\n=== 3. certified low-precision inference")

    @jax.jit
    def analyse_probs(xv):
        return PM.digits_forward(CaaOps(cfg), params, caa.weight(xv, cfg))

    n_cert = n_ok = 0
    for i in range(64):
        xi = imgs[i].astype(np.float64)
        p8 = analyse_probs(xi)
        pred = int(jnp.argmax(p8.val))
        if precision.classification_safe(np.asarray(p8.exact.lo),
                                         np.asarray(p8.exact.hi), pred):
            n_cert += 1
            ref = PM.digits_forward(JOps(jnp.float64, jnp.float64), params,
                                    jnp.asarray(xi))
            n_ok += int(int(jnp.argmax(ref)) == pred)
    print(f"    {n_cert}/64 inputs certified at k=8; "
          f"{n_ok}/{n_cert} certified decisions match the exact model "
          f"({'OK' if n_ok == n_cert else 'VIOLATION'})")


if __name__ == "__main__":
    main()
