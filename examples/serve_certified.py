"""End-to-end serving driver: batched requests against an LM with the
paper's certified low-precision arithmetic.

Serves a reduced qwen2-family model: prefills a batch of prompts, decodes
tokens with a KV cache, and (with --precision-k) runs every GEMM in the
certified k-bit emulation — the pipeline a low-precision inference chip
would execute, with error bars supplied by the CAA analysis.

With --certificates the precision is not hand-set: the repro.certify store
supplies (or creates, on first use) the persisted certificate for this
exact (arch, params), precision_k comes from it, and every response
carries the certified (δ̄, ε̄, k) error bars. Run it twice to see the
certified-vs-uncached difference: the first run pays the analysis, the
second is served from the store.

Run:  PYTHONPATH=src python examples/serve_certified.py --precision-k 12
      PYTHONPATH=src python examples/serve_certified.py --certificates certs/
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import (ServeConfig, apply_certificates,
                                build_serve_steps, make_responses)
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--precision-k", type=int, default=None,
                    help="run GEMMs in certified k-bit emulation")
    ap.add_argument("--certificates", default=None, metavar="STORE_DIR",
                    help="pick precision_k from the certificate store "
                         "(certifying on first use) and attach error bars")
    args = ap.parse_args()

    cfg = configs.get(args.arch).SMOKE
    sc = ServeConfig(arch=args.arch, batch=args.batch,
                     max_seq=args.prefill_len + args.decode_steps + 1,
                     prefill_len=args.prefill_len,
                     precision_k=args.precision_k,
                     certificates=args.certificates)
    rng = np.random.RandomState(0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    certset = None
    if sc.certificates is not None:
        t0 = time.perf_counter()
        sc, certset = apply_certificates(sc, cfg, params)
        t_cert = time.perf_counter() - t0
        src = ("store hit — no re-analysis"
               if certset.meta.get("from_store")
               else f"cold analysis ({certset.meta['analysis_seconds']:.2f}s)"
               " — persisted for next time")
        print(f"certificate fetch: {t_cert:.2f}s ({src})")
        print(f"  k={sc.precision_k}, error bars {certset.error_bars()}")

    mesh = make_host_mesh()
    with mesh:
        prefill, decode, _ = build_serve_steps(cfg, sc, mesh)
        cache = T.init_cache(cfg, sc.batch, sc.max_seq, jnp.float32)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab, (sc.batch, sc.prefill_len)))}

        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, batch)
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1, :], axis=-1)

        toks = [tok]
        t0 = time.perf_counter()
        for i in range(args.decode_steps):
            db = {"tokens": tok[:, None],
                  "pos": jnp.asarray(sc.prefill_len + i, jnp.int32)}
            tok, cache = decode(params, cache, db)
            toks.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    out = jnp.stack(toks, axis=1)
    responses = make_responses(out, certset)
    if sc.precision_k:
        mode = (f"certified k={sc.precision_k}"
                + (" (from certificate store)" if certset is not None else ""))
    else:
        mode = "full precision"
    print(f"served {args.batch} requests ({mode})")
    print(f"  prefill {sc.prefill_len} toks: {t_prefill:.2f}s  |  "
          f"decode {args.decode_steps} toks: {t_decode:.2f}s "
          f"({args.batch*args.decode_steps/t_decode:.1f} tok/s)")
    print(f"  sample continuation: {out[0][:12].tolist()}")
    if certset is not None:
        print(f"  response[0] error bars: {responses[0]['certificate']}")


if __name__ == "__main__":
    main()
