"""End-to-end serving driver: batched requests against an LM with the
paper's certified low-precision arithmetic.

Serves a reduced qwen2-family model: prefills a batch of prompts, decodes
tokens with a KV cache, and (with --precision-k) runs every GEMM in the
certified k-bit emulation — the pipeline a low-precision inference chip
would execute, with error bars supplied by the CAA analysis.

Run:  PYTHONPATH=src python examples/serve_certified.py --precision-k 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import ServeConfig, build_serve_steps
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--precision-k", type=int, default=None,
                    help="run GEMMs in certified k-bit emulation")
    args = ap.parse_args()

    cfg = configs.get(args.arch).SMOKE
    sc = ServeConfig(arch=args.arch, batch=args.batch,
                     max_seq=args.prefill_len + args.decode_steps + 1,
                     prefill_len=args.prefill_len,
                     precision_k=args.precision_k)
    mesh = make_host_mesh()
    rng = np.random.RandomState(0)

    with mesh:
        prefill, decode, _ = build_serve_steps(cfg, sc, mesh)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        cache = T.init_cache(cfg, sc.batch, sc.max_seq, jnp.float32)
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab, (sc.batch, sc.prefill_len)))}

        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, batch)
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1, :], axis=-1)

        toks = [tok]
        t0 = time.perf_counter()
        for i in range(args.decode_steps):
            db = {"tokens": tok[:, None],
                  "pos": jnp.asarray(sc.prefill_len + i, jnp.int32)}
            tok, cache = decode(params, cache, db)
            toks.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    out = jnp.stack(toks, axis=1)
    mode = (f"certified k={args.precision_k}" if args.precision_k
            else "full precision")
    print(f"served {args.batch} requests ({mode})")
    print(f"  prefill {sc.prefill_len} toks: {t_prefill:.2f}s  |  "
          f"decode {args.decode_steps} toks: {t_decode:.2f}s "
          f"({args.batch*args.decode_steps/t_decode:.1f} tok/s)")
    print(f"  sample continuation: {out[0][:12].tolist()}")


if __name__ == "__main__":
    main()
